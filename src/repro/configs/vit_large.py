"""ViT-Large (Hermes paper workload, Table I: 304M, 24 encoder layers).
d=1024, 16H, d_ff=4096, FP16 (~25 MB/layer per the paper).  The patch
embedder is out of scope for the loading pipeline (embedding layers are
"other layers" in the paper); inputs arrive as patch embeddings.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="vit-large",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=1000,          # classifier head
    vocab_pad_to=8,
    head_dim=64,
    causal=False,
    gated_mlp=False,
    dtype="float16",
)
LONG_CONFIG = None
