"""Zamba2-1.2B — Mamba2 backbone + weight-tied shared attention block.
[arXiv:2411.15242]  38L d_model=2048, shared attn 32H, d_ff=8192 (shared
block MLP), ssm_state=64, vocab=32000.
"""
from repro.models.config import MAMBA_HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family=MAMBA_HYBRID,
    num_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=128,           # attention at concat width 2*d_model = 32*128
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=6,    # 6 shared-attention sites over 38 layers
)

# long_500k: Mamba2 state is O(1); the shared attention sites switch to a
# 4096 sliding window so the hybrid stays sub-quadratic end to end.
LONG_CONFIG = CONFIG.with_(sliding_window=4096)
