"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention.
[hf:openbmb/MiniCPM3-4B]  62L d_model=2560 40H (MHA) d_ff=6400 vocab=73448.
MLA geometry per the model card: q_lora_rank=768, kv_lora_rank=256,
qk_rope_head_dim=32, v/qk_nope head dim 64.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family=DENSE,
    num_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0,
)

# long_500k: MLA latent cache is ~288 B/token — the 524k cache fits easily
# (see DESIGN.md); runs with the seq-sharded flash-decode path unchanged.
LONG_CONFIG = CONFIG
