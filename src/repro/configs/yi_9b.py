"""Yi-9B — llama-architecture dense GQA.  [arXiv:2403.04652]
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family=DENSE,
    num_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=10_000.0,
)

LONG_CONFIG = CONFIG.with_(sliding_window=8192)
