"""SeamlessM4T-medium backbone — encoder-decoder, audio frontend stubbed.
[arXiv:2308.11596]  12L enc + 12L dec, d_model=1024 16H d_ff=4096
vocab=256206.  ``input_specs`` supplies precomputed frame embeddings.
"""
from repro.models.config import ENCDEC, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=ENCDEC,
    num_layers=12,
    enc_layers=12,
    enc_seq_len=1024,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
)

# long_500k SKIPPED: enc-dec full self+cross attention, no sub-quadratic
# variant in the source model (DESIGN.md shape-coverage table).
LONG_CONFIG = None
