"""GPT-J-6B (Hermes paper workload, Table I: 28 decoder layers).
d=4096, 16H, d_ff=16384, vocab 50400.  NOTE: Table I labels GPT-J "FP32"
but its byte counts (12354 MB total, 412 MB/layer) imply 2 bytes/param;
we match the paper's BYTES (float16) — see EXPERIMENTS.md §Paper-validation.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gpt-j",
    family=DENSE,
    num_layers=28,
    d_model=4096,
    n_heads=16,
    n_kv_heads=16,
    d_ff=16384,
    vocab_size=50400,
    head_dim=256,
    gated_mlp=False,
    dtype="float16",
)
LONG_CONFIG = None
