"""xLSTM-1.3B — sLSTM + mLSTM stack (7:1).  [arXiv:2405.04517]
48L d_model=2048 4H vocab=50304; no FFN (d_ff=0): the mLSTM up-projection
carries the channel mixing.
"""
from repro.models.config import XLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family=XLSTM,
    num_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    slstm_every=8,          # 7 mLSTM : 1 sLSTM
    ssm_chunk=128,
)

LONG_CONFIG = CONFIG  # O(1) recurrent state: long_500k runs natively
