"""Deterministic multi-tenant serving traces (the serve-SLO harness).

A trace is the *input* half of a serving experiment: who arrives when,
with which prompt, wanting how many tokens, at what priority, for which
tenant.  Scheduler policy work lives or dies on replayability — a p99
that moves because the workload moved is noise, not signal — so the
generator here is fully seeded and shared verbatim by the property
tests (``tests/test_serve_slo.py``), the golden-trace regression test,
the CLI (``repro.launch.serve --trace/--tenants``) and the benchmark
(``benchmarks/bench_serve_slo.py``).

Workload shape (the usual serving mix, all seeded):

* **Poisson arrivals** on the scheduler's ROUND clock: exponential
  inter-arrival gaps at ``arrival_rate`` requests per round, cumulated
  and floored to integer round numbers.
* **Heavy-tailed prompt lengths**: lognormal, clipped to
  ``[4, max_prompt]`` — most prompts are short, the tail is what
  chunked prefill exists for.
* **Geometric output lengths** clipped to ``[1, max_new]``.
* **Tenant mix**: Zipf-weighted across ``tenants`` ids (tenant 0 is
  the heavy hitter), each tenant owning a deterministic system-prompt
  prefix that a ``share_prefix`` fraction of its requests reuse —
  exercising the per-tenant prefix namespaces without ever sharing
  tokens across tenants.
* **Priority classes** 0..2 drawn ``(70%, 20%, 10%)`` — rare
  high-priority arrivals are what preemption exists for.

Traces serialise to plain JSON (``save_trace``/``load_trace``) so a
golden file diff stays human-readable.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from pathlib import Path
from typing import Dict, List

import numpy as np

PRIORITY_MIX = (0.7, 0.2, 0.1)       # P(priority == 0, 1, 2)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival in a serving trace (tokens are plain ints)."""
    rid: int
    arrival_round: int
    tenant: str
    priority: int
    prompt: List[int]
    new_tokens: int


def tenant_prefix(tenant: str, length: int, vocab: int,
                  seed: int = 0) -> List[int]:
    """The tenant's deterministic "system prompt": same tokens for every
    request of that tenant (per seed), different across tenants.  Keyed
    by a stable digest (NOT ``hash()``, which is salted per process)."""
    digest = zlib.crc32(tenant.encode("utf-8"))
    h = np.random.default_rng([seed, digest])
    return [int(t) for t in h.integers(0, vocab, (length,))]


def make_trace(n_requests: int, *, tenants: int = 2, seed: int = 0,
               vocab: int = 1000, arrival_rate: float = 1.0,
               prompt_mean: int = 16, max_prompt: int = 48,
               new_mean: int = 6, max_new: int = 12,
               prefix_len: int = 0, share_prefix: float = 0.5
               ) -> List[TraceRequest]:
    """Seeded heavy-tailed multi-tenant Poisson trace (module docs).

    ``prefix_len`` > 0 prepends each tenant's system prompt to a
    ``share_prefix`` fraction of its requests (clipped so prompts stay
    within ``max_prompt``)."""
    rng = np.random.default_rng(seed)
    # Poisson arrivals on the round clock
    gaps = rng.exponential(1.0 / max(arrival_rate, 1e-9), n_requests)
    rounds = np.floor(np.cumsum(gaps)).astype(int)
    # Zipf tenant mix: tenant 0 is the heavy hitter
    w = 1.0 / np.arange(1, tenants + 1, dtype=np.float64)
    w /= w.sum()
    tids = rng.choice(tenants, size=n_requests, p=w)
    prios = rng.choice(len(PRIORITY_MIX), size=n_requests, p=PRIORITY_MIX)
    # heavy-tailed prompt lengths (lognormal), geometric output lengths
    plens = np.clip(rng.lognormal(np.log(max(prompt_mean, 4)), 0.6,
                                  n_requests).astype(int), 4, max_prompt)
    nnews = np.clip(rng.geometric(1.0 / max(new_mean, 1), n_requests),
                    1, max_new)
    prefixes = {t: tenant_prefix(f"t{t}", prefix_len, vocab, seed)
                for t in range(tenants)} if prefix_len else {}
    share = rng.random(n_requests) < share_prefix

    out: List[TraceRequest] = []
    for i in range(n_requests):
        body = [int(t) for t in rng.integers(0, vocab, (int(plens[i]),))]
        if prefix_len and share[i]:
            body = (prefixes[int(tids[i])] + body)[:max_prompt]
        out.append(TraceRequest(
            rid=i, arrival_round=int(rounds[i]), tenant=f"t{int(tids[i])}",
            priority=int(prios[i]), prompt=body,
            new_tokens=int(nnews[i])))
    return out


def trace_max_len(trace: List[TraceRequest]) -> int:
    """Smallest ``max_total_len`` that fits every request."""
    return max(len(r.prompt) + r.new_tokens for r in trace)


def save_trace(trace: List[TraceRequest], path) -> Path:
    path = Path(path)
    path.write_text(json.dumps([dataclasses.asdict(r) for r in trace],
                               indent=1))
    return path


def load_trace(path) -> List[TraceRequest]:
    rows = json.loads(Path(path).read_text())
    return [TraceRequest(**r) for r in rows]


def submit_trace(sched, trace: List[TraceRequest],
                 priorities: bool = True) -> Dict[int, int]:
    """Feed a trace into a ``BatchScheduler``; returns
    ``{trace rid -> scheduler rid}``.  ``priorities=False`` flattens
    every request to priority 0 (the FIFO baseline arm)."""
    return {r.rid: sched.submit(np.asarray(r.prompt, np.int32),
                                r.new_tokens,
                                arrival_round=r.arrival_round,
                                priority=(r.priority if priorities else 0),
                                tenant=r.tenant)
            for r in trace}
