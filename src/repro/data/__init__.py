from repro.data.synthetic import make_batch, batch_iterator  # noqa: F401
from repro.data.traces import (TraceRequest, load_trace,  # noqa: F401
                               make_trace, save_trace, submit_trace,
                               tenant_prefix, trace_max_len)
