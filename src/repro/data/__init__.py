from repro.data.synthetic import make_batch, batch_iterator  # noqa: F401
