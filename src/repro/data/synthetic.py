"""Seeded synthetic data pipeline.

Generates structurally-valid batches for every model family (tokens, labels,
patch embeddings, audio frame embeddings).  Tokens follow a mixture of a
Zipf-like unigram draw and short repeated motifs so a language model can
actually reduce loss during the end-to-end training example.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ENCDEC, VLM, ModelConfig


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    flat = rng.choice(vocab, size=int(np.prod(shape)), p=probs)
    toks = flat.reshape(shape).astype(np.int32)
    # repeated motifs: copy a short window forward so context is predictive
    if shape[-1] >= 16:
        toks[..., 8:16] = toks[..., 0:8]
    return toks


def make_batch(cfg: ModelConfig, batch: int, seq: int,
               seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.family == VLM:
        n_patch = cfg.num_patches
        assert seq > n_patch, (
            f"VLM seq {seq} must exceed num_patches {n_patch}")
        s_text = seq - n_patch
        toks = _zipf_tokens(rng, (batch, s_text + 1), cfg.vocab_size)
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, n_patch, cfg.d_model)) * 0.02,
            jnp.float32)
        out["tokens"] = jnp.asarray(toks[:, :-1])
        out["labels"] = jnp.asarray(toks[:, 1:])
        return out
    if cfg.family == ENCDEC:
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_seq_len, cfg.d_model)) * 0.02,
            jnp.float32)
        toks = _zipf_tokens(rng, (batch, seq + 1), cfg.vocab_size)
        out["tokens"] = jnp.asarray(toks[:, :-1])
        out["labels"] = jnp.asarray(toks[:, 1:])
        return out
    toks = _zipf_tokens(rng, (batch, seq + 1), cfg.vocab_size)
    out["tokens"] = jnp.asarray(toks[:, :-1])
    out["labels"] = jnp.asarray(toks[:, 1:])
    return out


def batch_iterator(cfg: ModelConfig, batch: int, seq: int,
                   seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    step = 0
    while True:
        yield make_batch(cfg, batch, seq, seed=seed + step)
        step += 1
